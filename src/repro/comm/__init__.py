"""repro.comm — the pluggable compressed-communication subsystem.

Everything that crosses the agent boundary during consensus goes through a
:class:`WireCodec`; both consensus engines (gather and permute) are codec
agnostic.  See ``codec.py`` for the protocol and the built-in codecs
(``identity``, ``bf16``, ``f16``, ``int8``, ``topk``) and ``accounting.py``
for codec-aware bytes-on-wire math.
"""
from repro.comm.accounting import (
    collective_bytes_per_step,
    compression_ratio,
    wire_bytes,
)
from repro.comm.codec import (
    CastCodec,
    IdentityCodec,
    Int8StochasticCodec,
    QuantLeaf,
    TopKCodec,
    WireCodec,
    codec_names,
    init_comm_state,
    make_codec,
    register_codec,
    topk_threshold,
)
from repro.comm.rng import counter_uniform

__all__ = [
    "WireCodec",
    "IdentityCodec",
    "CastCodec",
    "Int8StochasticCodec",
    "TopKCodec",
    "QuantLeaf",
    "make_codec",
    "register_codec",
    "codec_names",
    "init_comm_state",
    "counter_uniform",
    "topk_threshold",
    "wire_bytes",
    "collective_bytes_per_step",
    "compression_ratio",
]
