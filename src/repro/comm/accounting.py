"""Codec-aware analytic accounting of collective volume.

Replaces the fixed-f32 ``collective_bytes_per_step`` in
``repro.core.consensus`` (kept there as a thin delegating shim): the wire
volume of one consensus round is the codec's per-agent wire bytes scaled by
the topology/engine exchange pattern, not the raw parameter bytes.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.comm.codec import IdentityCodec, WireCodec, make_codec

PyTree = Any


def wire_bytes(template: PyTree, codec: "WireCodec | str | None" = None) -> int:
    """Bytes ONE agent puts on the wire per exchange round under ``codec``.

    ``template``: a single-agent parameter tree (arrays or
    ShapeDtypeStructs)."""
    return make_codec(codec).wire_bytes(template)


def collective_bytes_per_step(
    topology,
    template: "PyTree | int",
    engine: str,
    codec: "WireCodec | str | None" = None,
) -> dict[str, int]:
    """Analytic collective volume of ONE consensus step, per agent.

    ``template`` is a single-agent parameter tree (preferred — enables codec
    accounting) or a raw ``param_bytes`` int (legacy; only valid with the
    identity codec since compressed volume depends on leaf shapes).

    gather engine: all-gather of the agent-stacked wire tree => (K-1) x
    wire_bytes received per agent.  permute engine: one ppermute per exchange
    round => n_rounds x wire_bytes.
    """
    from repro.core.consensus import (  # lazy: no cycle
        matching_decomposition,
        permutation_decomposition,
    )

    resolved = make_codec(codec)
    if isinstance(template, int):
        if not isinstance(resolved, IdentityCodec):
            raise TypeError(
                "codec-aware accounting needs a parameter tree template, "
                "not raw param_bytes"
            )
        per_round = template
    else:
        per_round = resolved.wire_bytes(template)

    K = topology.num_agents
    if engine == "gather":
        return {"recv_bytes": (K - 1) * per_round, "rounds": 1}
    decomp = permutation_decomposition(topology)
    if decomp is None:
        # what the engine actually runs for decomposition-less graphs (chain,
        # churn-realized topologies): one ppermute per greedy matching —
        # keeps the analytic number equal to the runtime wire counters
        decomp = matching_decomposition(topology)
    return {"recv_bytes": len(decomp) * per_round, "rounds": len(decomp)}


def compression_ratio(template: PyTree, codec: "WireCodec | str | None") -> float:
    """f32-equivalent bytes / codec wire bytes (>= 1 for real compression)."""
    dense = IdentityCodec().wire_bytes(template)
    return dense / max(wire_bytes(template, codec), 1)
