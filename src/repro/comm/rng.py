"""Counter-based uniforms for stochastic rounding.

``jax.random.uniform`` runs the full threefry2x32 block cipher per draw.
That is the right tool for statistical work, but the int8 wire codec draws
one uniform per parameter per agent per consensus round (K x D ~ 4M draws a
round on the benchmark model) purely to break rounding ties, and on CPU the
threefry pass alone costs more than the whole exact consensus round-set
(~30 ms vs ~10 ms measured at K=16).  Stochastic rounding needs decorrelated,
unbiased tie-breaks — not a CSPRNG.

``counter_uniform`` is the cheap drop-in: a murmur3-style integer hash
(``fmix32`` double avalanche) of ``(key word 0, key word 1, element index)``.
It is

* **stateless / counter-based** — u[i] depends only on the key and the
  element's linear index, so the slab fast path, the per-leaf tree codec and
  the Pallas kernels can all compute the SAME bits from static index maps
  (wire bit-parity across every path), in any order, with no carried state;
* **~20x cheaper than threefry on CPU** (two 5-op avalanche rounds per draw,
  all vectorizable int32 ALU work, no odd/even lane recombination);
* **computable inside a Pallas kernel** — plain uint32 arithmetic on an iota,
  which is exactly what the fused encode kernels do ("in-kernel RNG").

The derivation contract every caller shares: a leaf's uniforms are
``uniform_from_words(w0, w1, idx)`` where ``(w0, w1)`` are the LAST TWO words
of ``jax.random.key_data`` of the per-leaf key (threefry keys have exactly
two) and ``idx`` is the element's row-major linear index within the leaf.
Key splitting/folding stays ordinary jax.random — only the per-element draw
is replaced.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

_PHI = np.uint32(0x9E3779B9)  # 2^32 / golden ratio: index stride constant
_C1 = np.uint32(0x85EBCA6B)  # murmur3 fmix32 multipliers
_C2 = np.uint32(0xC2B2AE35)
_INV24 = np.float32(2.0**-24)


def fmix32(x):
    """murmur3 32-bit finalizer: full avalanche (every input bit flips each
    output bit with p~=0.5).  ``x`` is a uint32 array; ops wrap mod 2^32."""
    x = x ^ (x >> np.uint32(16))
    x = x * _C1
    x = x ^ (x >> np.uint32(13))
    x = x * _C2
    x = x ^ (x >> np.uint32(16))
    return x


def counter_bits(w0, w1, idx):
    """uint32 hash of (key words, element counter); broadcasts like jnp ops.

    Two chained avalanches with the second key word injected between them —
    adjacent counters and adjacent fold_in keys land in unrelated places.
    """
    x = idx.astype(jnp.uint32) * _PHI + w0.astype(jnp.uint32)
    x = fmix32(x) ^ w1.astype(jnp.uint32)
    return fmix32(x)


def bits_to_uniform(bits):
    """uint32 -> f32 U[0, 1): top 24 bits scaled by 2^-24 (every value is an
    exact f32; 1.0 is never produced, so ``floor(x/s + u)`` never rounds a
    representable value past its ceiling)."""
    return (bits >> np.uint32(8)).astype(jnp.float32) * _INV24


def key_words(key):
    """Last two uint32 words of a typed (or raw uint32) PRNG key.

    Threefry keys have exactly two words; wider impls (rbg) contribute their
    last two — the split/fold_in derivation upstream already mixed the rest.
    """
    data = key if jnp.issubdtype(jnp.asarray(key).dtype, jnp.integer) else jax.random.key_data(key)
    data = jnp.asarray(data, jnp.uint32)
    return data[..., -2], data[..., -1]


def uniform_from_words(w0, w1, idx):
    """The shared per-element rule: f32 U[0,1) from key words + linear index."""
    return bits_to_uniform(counter_bits(w0, w1, idx))


def counter_uniform(key, shape):
    """U[0, 1) f32 draws of ``shape`` from a jax PRNG key — the cheap
    stochastic-rounding replacement for ``jax.random.uniform(key, shape)``.

    Element ``i`` (row-major) gets ``uniform_from_words(w0, w1, i)``; any
    other path (slab regions, Pallas blocks) reproduces the same bits from
    the same linear indices.
    """
    w0, w1 = key_words(key)
    n = math.prod(shape) if shape else 1
    idx = jnp.arange(n, dtype=jnp.uint32)
    return uniform_from_words(w0, w1, idx).reshape(shape)
