"""Synthetic CIFAR-like classification data + the paper's non-IID partitioner.

Real CIFAR-10 cannot be downloaded in this container (DESIGN.md §7).  The
synthetic task: each class c has a set of random spatial "prototype" patterns
mixed through a shared random convolutional basis, plus per-sample noise and
random shifts — learnable by a small CNN, non-trivially (a linear model does
not saturate it).  Absolute accuracies are not comparable to real CIFAR-10;
the DRT-vs-classical comparisons across topologies are.

The non-IID partition follows §IV.A exactly: each agent draws its number of
classes uniformly from {5..8} and its sample count from {1500..2000}, sampled
without replacement from the pool.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CifarLikeConfig:
    num_classes: int = 10
    image_size: int = 32
    channels: int = 3
    prototypes_per_class: int = 3
    noise: float = 0.4
    max_shift: int = 2
    seed: int = 0


class CifarLike:
    def __init__(self, cfg: CifarLikeConfig = CifarLikeConfig()):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        s, ch, C, P = cfg.image_size, cfg.channels, cfg.num_classes, cfg.prototypes_per_class
        # low-frequency class prototypes: random coarse grids upsampled
        coarse = rng.normal(size=(C, P, 8, 8, ch)).astype(np.float32)
        up = coarse.repeat(s // 8, axis=2).repeat(s // 8, axis=3)
        self.prototypes = up  # (C, P, s, s, ch)

    def sample(self, n: int, rng: np.random.Generator, classes=None):
        cfg = self.cfg
        classes = np.asarray(classes if classes is not None else np.arange(cfg.num_classes))
        labels = rng.choice(classes, size=n)
        proto_idx = rng.integers(0, cfg.prototypes_per_class, size=n)
        imgs = self.prototypes[labels, proto_idx].copy()  # (n, s, s, ch)
        # random circular shifts (translation invariance pressure)
        for i in range(n):
            dx, dy = rng.integers(-cfg.max_shift, cfg.max_shift + 1, size=2)
            imgs[i] = np.roll(np.roll(imgs[i], dx, axis=0), dy, axis=1)
        imgs += rng.normal(scale=cfg.noise, size=imgs.shape).astype(np.float32)
        return imgs.astype(np.float32), labels.astype(np.int32)

    # -- the paper's §IV.A non-IID partition ---------------------------------

    def paper_partition(
        self,
        num_agents: int = 16,
        min_classes: int = 5,
        max_classes: int = 8,
        min_samples: int = 1500,
        max_samples: int = 2000,
        seed: int = 0,
    ):
        """Returns per-agent train sets: list of (images, labels)."""
        rng = np.random.default_rng(seed)
        shards = []
        for _ in range(num_agents):
            n_cls = rng.integers(min_classes, max_classes + 1)
            classes = rng.choice(self.cfg.num_classes, size=n_cls, replace=False)
            n = int(rng.integers(min_samples, max_samples + 1))
            shards.append(self.sample(n, rng, classes=classes))
        return shards

    # -- Dirichlet label-skew partition (Hsu et al.) -------------------------

    def dirichlet_partition(
        self,
        num_agents: int = 16,
        alpha: float = 0.3,
        samples_per_agent: int = 256,
        seed: int = 0,
    ):
        """Per-agent shards with Dirichlet(alpha) label skew: sample a shared
        pool, then split it with :func:`repro.data.partition.dirichlet_partition`
        (alpha -> 0 = near-disjoint labels, alpha -> inf = IID).  Same output
        format as :meth:`paper_partition`."""
        from repro.data.partition import dirichlet_shards

        rng = np.random.default_rng(seed)
        x, y = self.sample(num_agents * samples_per_agent, rng)
        return dirichlet_shards(
            x, y, num_agents, alpha=alpha, seed=seed,
            min_per_agent=max(1, samples_per_agent // 4),
        )

    def test_set(self, n: int = 2000, seed: int = 10_000):
        rng = np.random.default_rng(seed)
        return self.sample(n, rng)


def agent_minibatches(shards, batch_size: int, epoch_seed: int):
    """One epoch of aligned per-agent minibatches.

    Each agent iterates its own shard (shuffled per epoch); the epoch length
    is the MINIMUM number of full batches across agents so the returned array
    stacks to (n_batches, K, batch, ...)."""
    rng = np.random.default_rng(epoch_seed)
    K = len(shards)
    n_batches = min(len(x) // batch_size for x, _ in shards)
    imgs, labs = [], []
    for x, y in shards:
        perm = rng.permutation(len(x))[: n_batches * batch_size]
        imgs.append(x[perm].reshape(n_batches, batch_size, *x.shape[1:]))
        labs.append(y[perm].reshape(n_batches, batch_size))
    return {
        "images": np.stack(imgs, axis=1),  # (n_batches, K, B, s, s, ch)
        "labels": np.stack(labs, axis=1),  # (n_batches, K, B)
    }
