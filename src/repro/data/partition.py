"""Non-IID data partitioners for decentralized training.

The paper's §IV.A partition (each agent draws 5-8 classes; see
``CifarLike.paper_partition``) is one heterogeneity model.  The standard
knob in the federated/decentralized literature is the **Dirichlet
partitioner** (Hsu et al., 2019): for every class, draw a proportion vector
over agents from ``Dir(alpha)`` and split that class's samples accordingly.
``alpha -> 0`` gives near-disjoint label distributions (extreme non-IID),
``alpha -> inf`` recovers IID.  This is the partitioner the scenario-matrix
benchmarks sweep against topology schedules — label skew is exactly what
makes sparse/dynamic graphs stress the consensus step.
"""
from __future__ import annotations

import numpy as np


def dirichlet_partition(
    labels,
    num_agents: int,
    alpha: float = 0.3,
    seed: int = 0,
    min_per_agent: int = 1,
    max_tries: int = 100,
) -> list[np.ndarray]:
    """Split sample indices over agents with per-class Dirichlet proportions.

    ``labels``: (N,) integer class labels.  Returns ``num_agents`` index
    arrays (shuffled, disjoint, covering all N samples).  Resamples the
    proportions (up to ``max_tries``) until every agent holds at least
    ``min_per_agent`` samples, so downstream per-agent batching is total.
    """
    labels = np.asarray(labels)
    if labels.ndim != 1:
        raise ValueError(f"labels must be 1-d, got shape {labels.shape}")
    if num_agents < 1:
        raise ValueError(f"num_agents must be >= 1, got {num_agents}")
    if alpha <= 0:
        raise ValueError(f"Dirichlet alpha must be > 0, got {alpha}")
    if len(labels) < num_agents * min_per_agent:
        raise ValueError(
            f"{len(labels)} samples cannot give {num_agents} agents "
            f">= {min_per_agent} each"
        )
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    for _ in range(max_tries):
        shards: list[list[np.ndarray]] = [[] for _ in range(num_agents)]
        for c in classes:
            idx = np.flatnonzero(labels == c)
            rng.shuffle(idx)
            props = rng.dirichlet(np.full(num_agents, alpha))
            # cumulative split points; len(idx) lands on the last agent
            cuts = (np.cumsum(props)[:-1] * len(idx)).astype(np.int64)
            for k, part in enumerate(np.split(idx, cuts)):
                shards[k].append(part)
        out = [np.concatenate(s) if s else np.empty(0, np.int64) for s in shards]
        if min(len(o) for o in out) >= min_per_agent:
            for o in out:
                rng.shuffle(o)
            return out
    raise ValueError(
        f"could not satisfy min_per_agent={min_per_agent} in {max_tries} "
        f"tries (alpha={alpha} too small for K={num_agents}?)"
    )


def dirichlet_shards(
    images,
    labels,
    num_agents: int,
    alpha: float = 0.3,
    seed: int = 0,
    min_per_agent: int = 1,
) -> list[tuple[np.ndarray, np.ndarray]]:
    """Convenience: materialize per-agent ``(images, labels)`` shards in the
    same format as ``CifarLike.paper_partition`` (consumable by
    ``agent_minibatches``)."""
    images = np.asarray(images)
    labels = np.asarray(labels)
    parts = dirichlet_partition(
        labels, num_agents, alpha=alpha, seed=seed, min_per_agent=min_per_agent
    )
    return [(images[p], labels[p]) for p in parts]


def label_distribution(shards, num_classes: int) -> np.ndarray:
    """(K, num_classes) per-agent label histogram — the heterogeneity report
    the scenario benchmarks log next to the disagreement gap."""
    out = np.zeros((len(shards), num_classes), np.int64)
    for k, (_, y) in enumerate(shards):
        np.add.at(out[k], np.asarray(y), 1)
    return out
