"""Synthetic LM token streams (offline container — no real corpora).

Tokens are generated from a per-agent Markov-ish process with learnable
structure (a random low-order transition table), so cross-entropy genuinely
decreases during training and per-agent distributions can be made non-IID by
giving each agent a different transition table mixture.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStreamConfig:
    vocab: int
    seq_len: int
    n_tables: int = 4  # distinct base transition tables
    order: int = 1
    alpha: float = 0.05  # dirichlet concentration; small = peaky = learnable
    v_eff: int = 64  # effective vocab (bigram table stays learnably small)
    seed: int = 0


class SyntheticTokenStream:
    """Deterministic, restartable synthetic token source."""

    def __init__(self, cfg: TokenStreamConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = min(cfg.vocab, cfg.v_eff)  # effective vocab (rest unused — realistic tail)
        self.v_eff = v
        self.tables = rng.dirichlet(
            np.full(v, cfg.alpha), size=(cfg.n_tables, v)
        )  # (T, v, v)

    def batch(self, batch_size: int, agent: int = 0, step: int = 0) -> np.ndarray:
        """(batch_size, seq_len + 1) int32 tokens.  Per-agent non-IID: agent k
        samples from table k mod n_tables."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + agent * 10_007 + step) % (2**63)
        )
        table = self.tables[agent % cfg.n_tables]
        out = np.empty((batch_size, cfg.seq_len + 1), np.int32)
        cur = rng.integers(0, self.v_eff, size=batch_size)
        out[:, 0] = cur
        # vectorized ancestral sampling via inverse-CDF
        cdf = np.cumsum(table, axis=1)
        for t in range(1, cfg.seq_len + 1):
            u = rng.random(batch_size)
            cur = (cdf[cur] < u[:, None]).sum(axis=1).clip(0, self.v_eff - 1)
            out[:, t] = cur
        return out

    def agent_batches(self, batch_size: int, num_agents: int, step: int = 0) -> np.ndarray:
        """(num_agents, batch_size, seq_len + 1) — one non-IID batch per agent."""
        return np.stack(
            [self.batch(batch_size, agent=k, step=step) for k in range(num_agents)]
        )
