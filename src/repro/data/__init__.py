from repro.data.synthetic import SyntheticTokenStream, TokenStreamConfig
from repro.data.cifar_like import CifarLike, CifarLikeConfig, agent_minibatches
from repro.data.partition import (
    dirichlet_partition,
    dirichlet_shards,
    label_distribution,
)

__all__ = [
    "SyntheticTokenStream",
    "TokenStreamConfig",
    "CifarLike",
    "CifarLikeConfig",
    "agent_minibatches",
    "dirichlet_partition",
    "dirichlet_shards",
    "label_distribution",
]
