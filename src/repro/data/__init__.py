from repro.data.synthetic import SyntheticTokenStream, TokenStreamConfig
from repro.data.cifar_like import CifarLike, CifarLikeConfig, agent_minibatches

__all__ = [
    "SyntheticTokenStream",
    "TokenStreamConfig",
    "CifarLike",
    "CifarLikeConfig",
    "agent_minibatches",
]
