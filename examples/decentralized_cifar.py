"""Paper reproduction driver (§IV): 16 agents, ResNet-20, CIFAR-like task.

Reproduces the Table I / Fig. 1 / Fig. 2 experiment protocol: non-IID shards
(5-8 classes, per-agent sample budget), one local epoch per round, 3
consensus steps, N = 2K, across {ring, erdos_renyi, hypercube} x
{classical, drt}.  Real CIFAR-10 is unavailable offline; the synthetic
CIFAR-like task preserves the comparisons (DESIGN.md §7).

Defaults are CPU-budgeted (reduced width/samples/epochs); crank
--width 16 --min-samples 1500 --max-samples 2000 --epochs 60 --image-size 32
for the paper's full protocol on real hardware.

Run:  PYTHONPATH=src python examples/decentralized_cifar.py --epochs 8
"""
import argparse
import csv
import sys
import time

import jax
import jax.numpy as jnp

from repro.core import DecentralizedTrainer, TrainerConfig, make_topology
from repro.core.topology import PAPER_ER_SEED
from repro.data import CifarLike, CifarLikeConfig, agent_minibatches
from repro.models.resnet import init_resnet20, resnet20_accuracy, resnet20_loss
from repro.optim import adamw, momentum


def run_experiment(args, topology_name: str, algorithm: str, data, shards, test):
    K = args.agents
    if topology_name == "erdos_renyi":
        topo = make_topology("erdos_renyi", K, p=0.1, seed=PAPER_ER_SEED)
    else:
        topo = make_topology(topology_name, K)
    opt = adamw(args.lr) if args.optimizer == "adam" else momentum(args.lr, 0.9)
    tr = DecentralizedTrainer(
        lambda p, b, rng: resnet20_loss(p, b),
        lambda key: init_resnet20(key, width=args.width),
        opt,
        topo,
        TrainerConfig(algorithm=algorithm, consensus_steps=3, codec=args.codec),
    )
    st = tr.init(jax.random.key(0))
    epoch_fn = jax.jit(tr.epoch)
    history = []
    for e in range(args.epochs):
        b = agent_minibatches(shards, batch_size=args.batch, epoch_seed=e)
        batches = {"images": jnp.asarray(b["images"]), "labels": jnp.asarray(b["labels"])}
        st, m = epoch_fn(st, batches, jax.random.key(e))
        # evaluate agent 0 (all agents are statistically equivalent)
        p0 = jax.tree.map(lambda x: x[0], st.params)
        test_acc = float(resnet20_accuracy(p0, {"images": test[0], "labels": test[1]}))
        tr_imgs = jnp.asarray(shards[0][0][: len(test[1])])
        tr_labs = jnp.asarray(shards[0][1][: len(test[1])])
        train_acc = float(resnet20_accuracy(p0, {"images": tr_imgs, "labels": tr_labs}))
        history.append(
            dict(epoch=e, loss=float(m["loss"]), test_acc=test_acc, train_acc=train_acc,
                 gen_gap=train_acc - test_acc, disagreement=float(m["disagreement"])),
        )
    return topo.lambda2(), history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=16)
    ap.add_argument("--width", type=int, default=8)
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--optimizer", default="adam", choices=["adam", "momentum"])
    ap.add_argument("--noise", type=float, default=0.1)
    ap.add_argument("--min-samples", type=int, default=256)
    ap.add_argument("--max-samples", type=int, default=320)
    ap.add_argument("--topologies", default="ring,erdos_renyi,hypercube")
    ap.add_argument(
        "--codec", default=None,
        help="wire codec for the consensus exchange: identity|bf16|f16|int8|"
             "topk[:frac] (default: exact f32 exchange)",
    )
    ap.add_argument("--out-csv", default=None)
    args = ap.parse_args(argv)

    if args.codec:
        from repro.comm import compression_ratio

        # allocation-free: the accounting works on ShapeDtypeStructs
        template = jax.eval_shape(
            lambda k: init_resnet20(k, width=args.width), jax.random.key(0)
        )
        print(f"consensus wire codec: {args.codec} "
              f"({compression_ratio(template, args.codec):.1f}x vs f32)")

    data = CifarLike(CifarLikeConfig(image_size=args.image_size, noise=args.noise, max_shift=0))
    shards = data.paper_partition(
        num_agents=args.agents, min_samples=args.min_samples,
        max_samples=args.max_samples, seed=1,
    )
    tx, ty = data.test_set(512)
    test = (jnp.asarray(tx), jnp.asarray(ty))

    rows = []
    print(f"{'topology':12s} {'lambda2':>8s} {'algorithm':>10s} {'test acc':>9s} "
          f"{'gen gap':>8s} {'disagree':>9s}  time")
    for topo_name in args.topologies.split(","):
        for algo in ("classical", "drt"):
            t0 = time.time()
            lam2, hist = run_experiment(args, topo_name, algo, data, shards, test)
            last = hist[-1]
            print(f"{topo_name:12s} {lam2:8.3f} {algo:>10s} {last['test_acc']:9.3f} "
                  f"{last['gen_gap']:8.3f} {last['disagreement']:9.2f}  {time.time()-t0:.0f}s",
                  flush=True)
            for h in hist:
                rows.append(dict(topology=topo_name, lambda2=lam2, algorithm=algo, **h))
    if args.out_csv:
        with open(args.out_csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
        print(f"wrote {args.out_csv}")


if __name__ == "__main__":
    main()
