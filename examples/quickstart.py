"""Quickstart: DRT diffusion vs classical diffusion in ~60 seconds on CPU.

Eight agents, a tiny MLP classifier, non-IID shards of a synthetic 2-D task.
Shows the paper's core effect: DRT diffusion reaches the same (or better)
consensus solution while *permitting* larger parameter-space disagreement —
consensus happens in function space.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DecentralizedTrainer, TrainerConfig, ring
from repro.optim import momentum

K = 8
DIM, CLASSES = 16, 4


def make_data(seed=0, n_per_agent=256):
    """Non-IID: each agent sees only 2 of the 4 classes."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(CLASSES, DIM)) * 0.8
    shards = []
    for k in range(K):
        cls = np.array([k % CLASSES, (k + 1) % CLASSES])
        y = rng.choice(cls, size=n_per_agent)
        x = centers[y] + rng.normal(size=(n_per_agent, DIM)) * 1.2
        shards.append((x.astype(np.float32), y.astype(np.int32)))
    # IID test set
    yt = rng.integers(0, CLASSES, size=512)
    xt = centers[yt] + rng.normal(size=(512, DIM)) * 1.2
    return shards, (jnp.asarray(xt.astype(np.float32)), jnp.asarray(yt.astype(np.int32)))


def init_fn(key):
    k1, k2 = jax.random.split(key)
    return {
        "embed": {"w": jax.random.normal(k1, (DIM, 32)) * 0.3, "b": jnp.zeros((32,))},
        "blocks": {"w": jax.random.normal(k2, (2, 32, 32)) * 0.3, "b": jnp.zeros((2, 32))},
        "head": {"w": jnp.zeros((32, CLASSES)), "b": jnp.zeros((CLASSES,))},
    }


def forward(p, x):
    h = jax.nn.relu(x @ p["embed"]["w"] + p["embed"]["b"])
    for i in range(2):
        h = jax.nn.relu(h @ p["blocks"]["w"][i] + p["blocks"]["b"][i]) + h
    return h @ p["head"]["w"] + p["head"]["b"]


def loss_fn(p, batch, rng):
    x, y = batch
    logits = forward(p, x)
    return -jnp.mean(
        jnp.take_along_axis(jax.nn.log_softmax(logits), y[:, None], axis=1)
    )


def accuracy(p, x, y):
    return float(jnp.mean((jnp.argmax(forward(p, x), -1) == y).astype(jnp.float32)))


def main():
    shards, (xt, yt) = make_data()
    xs = jnp.stack([jnp.asarray(x) for x, _ in shards])
    ys = jnp.stack([jnp.asarray(y) for _, y in shards])

    print(f"{'algorithm':12s} {'test acc':>9s} {'local loss':>11s} {'disagreement':>13s}  time")
    for algo in ("classical", "drt"):
        tr = DecentralizedTrainer(
            loss_fn, init_fn, momentum(0.1, 0.9), ring(K),
            TrainerConfig(algorithm=algo, consensus_steps=3),
        )
        st = tr.init(jax.random.key(0))
        step = jax.jit(tr.local_step)
        cons = jax.jit(tr.consensus)
        t0 = time.time()
        for i in range(150):
            idx = jax.random.randint(jax.random.key(i), (K, 64), 0, xs.shape[1])
            batch = (
                jnp.take_along_axis(xs, idx[..., None], axis=1),
                jnp.take_along_axis(ys, idx, axis=1),
            )
            st, m = step(st, batch, jax.random.key(i))
            st, _ = cons(st)
        p0 = jax.tree.map(lambda v: v[0], st.params)
        acc = accuracy(p0, xt, yt)
        dis = float(tr.disagreement(st.params))
        print(f"{algo:12s} {acc:9.3f} {float(m['loss']):11.4f} {dis:13.4f}  {time.time()-t0:.0f}s")
    print("\nDRT keeps agents' *functions* aligned while their parameters drift —")
    print("the disagreement column is the paper's §II story in one number.")


if __name__ == "__main__":
    main()
