"""Batched serving example: prefill a batch of prompts, decode new tokens.

Exercises the full serving stack (prefill -> KV caches incl. SWA ring
buffers / SSM state -> jit'd decode loop) on a smoke-scale model; the full
configs run the same code path via the multi-pod dry-run.

Run:  PYTHONPATH=src python examples/serve_requests.py --arch gemma3-27b-smoke
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
