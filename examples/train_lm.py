"""End-to-end decentralized LM pretraining driver.

Trains a decoder LM with DRT diffusion over K agents on non-IID synthetic
token streams, with checkpointing and eval.  Presets:

  tiny   (default)  ~1M params, 4 agents, CPU ~2 min — smoke-scale demo
  small             ~15M params, 4 agents — minutes on CPU
  100m              ~110M params, 8 agents, a few hundred steps — the
                    assignment's "train a ~100M model" driver (hours on CPU;
                    the configuration is the deliverable, run it on a pod)

Run:  PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 100
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import make_topology
from repro.core.decentralized import TrainerConfig
from repro.data.synthetic import SyntheticTokenStream, TokenStreamConfig
from repro.launch.train import init_train_state, make_train_step
from repro.models.config import AttnCfg, GroupCfg, LayerCfg, ModelConfig
from repro.models.registry import build_bundle
from repro.optim import adamw
from repro.optim.schedule import linear_warmup_cosine
from repro.utils import tree_size

PRESETS = {
    "tiny": dict(layers=2, d_model=128, heads=4, kv=2, d_ff=384, vocab=512, agents=4,
                 batch=4, seq=64),
    "small": dict(layers=6, d_model=384, heads=6, kv=2, d_ff=1152, vocab=4096, agents=4,
                  batch=4, seq=128),
    "100m": dict(layers=12, d_model=768, heads=12, kv=4, d_ff=2304, vocab=32768, agents=8,
                 batch=8, seq=512),
}


def make_cfg(p) -> ModelConfig:
    return ModelConfig(
        name="train-lm",
        family="dense",
        d_model=p["d_model"],
        vocab=p["vocab"],
        d_ff=p["d_ff"],
        attn=AttnCfg(n_heads=p["heads"], n_kv_heads=p["kv"],
                     head_dim=p["d_model"] // p["heads"], qk_norm=True),
        groups=(GroupCfg(name="main", repeat=p["layers"], unit=(LayerCfg("attn_mlp"),)),),
        param_dtype="float32",
        compute_dtype="float32",
        num_agents=p["agents"],
        remat=False,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--algorithm", default="drt", choices=["drt", "classical"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--eval-every", type=int, default=25)
    args = ap.parse_args(argv)

    p = PRESETS[args.preset]
    cfg = make_cfg(p)
    bundle = build_bundle(cfg)
    K = cfg.num_agents
    topo = make_topology(args.topology, K)
    opt = adamw(linear_warmup_cosine(args.lr, args.warmup, args.steps))
    step = jax.jit(
        make_train_step(bundle, topo, opt, TrainerConfig(algorithm=args.algorithm))
    )
    state = init_train_state(bundle, opt, jax.random.key(0))
    n_params = tree_size(jax.eval_shape(bundle.init, jax.random.key(0)))
    print(f"preset={args.preset}: {n_params/1e6:.1f}M params/agent x {K} agents, "
          f"{args.algorithm} on {args.topology}")

    stream = SyntheticTokenStream(TokenStreamConfig(vocab=cfg.vocab, seq_len=p["seq"]))
    t0 = time.time()
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(stream.agent_batches(p["batch"], K, step=i))}
        state, metrics = step(state, batch, jax.random.key(i))
        if i % args.eval_every == 0 or i == args.steps - 1:
            tok_s = (i + 1) * K * p["batch"] * p["seq"] / (time.time() - t0)
            print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  ({tok_s:,.0f} tok/s)",
                  flush=True)
    if args.ckpt_dir:
        from repro.ckpt import save_checkpoint

        path = save_checkpoint(args.ckpt_dir, int(state.step), state.params)
        print(f"saved {path}")


if __name__ == "__main__":
    main()
